import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this compiles the real step function for the production mesh,
prints/records ``memory_analysis()`` (proves fit) and ``cost_analysis()``
(FLOPs/bytes for the roofline), parses the collective schedule from the
partitioned HLO, and compiles one-superlayer probes to scale scan-body costs
(see launch/probes.py). Results land in experiments/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import param_specs as psp
from repro.distributed.partition import make_rules, sanitize_spec, use_rules
from repro.launch import probes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (build_decode_step, build_prefill_step,
                                build_train_step, default_optimizer)
from repro.models.model import SHAPES, ModelApi

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def input_specs(arch: str, shape: str = "train_4k") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    return ModelApi(get_config(arch)).input_specs(shape)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
                       r"f8e4m3fn|f8e5m2|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dtype, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in partitioned HLO (per device)."""
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("//"):
            continue
        for kind in _COLLECTIVES:
            token = f" {kind}("
            alt = f" {kind}-start("
            idx = ls.find(token)
            if idx < 0:
                idx = ls.find(alt)
            if idx < 0 or "=" not in ls[:idx]:
                continue
            operands = ls[idx:]
            shapes = _SHAPE_RE.finditer(operands)
            b = sum(_shape_bytes(m) for m in shapes)
            if b == 0:  # operands printed without types; fall back to result
                res = _SHAPE_RE.finditer(ls[:idx])
                b = sum(_shape_bytes(m) for m in res)
            per_kind[kind] += b
            counts[kind] += 1
            break
    total = sum(per_kind.values())
    return {"bytes_per_kind": per_kind, "counts": counts, "total_bytes": total}


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def tree_shardings(mesh, spec_tree, shape_tree):
    is_p = lambda x: isinstance(x, P)

    def mk(spec, aval):
        return NamedSharding(mesh, sanitize_spec(spec, aval.shape, mesh))

    return jax.tree.map(mk, spec_tree, shape_tree, is_leaf=is_p)


def _cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older JAX: one dict per device
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def _mem_summary(compiled) -> Dict[str, float]:
    ms = compiled.memory_analysis()
    return {
        "argument_bytes": float(ms.argument_size_in_bytes),
        "output_bytes": float(ms.output_size_in_bytes),
        "temp_bytes": float(ms.temp_size_in_bytes),
        "alias_bytes": float(ms.alias_size_in_bytes),
        "peak_estimate_bytes": float(ms.argument_size_in_bytes
                                     + ms.temp_size_in_bytes
                                     + ms.output_size_in_bytes
                                     - ms.alias_size_in_bytes),
    }


def _compile(fn, in_shardings, args, donate=None) -> Tuple[Any, Dict[str, Any], float]:
    t0 = time.time()
    jfn = jax.jit(fn, in_shardings=in_shardings,
                  donate_argnums=donate or ())
    lowered = jfn.lower(*args)
    compiled = lowered.compile()
    dt = time.time() - t0
    text = compiled.as_text()
    info = {
        "cost": _cost_summary(compiled),
        "memory": _mem_summary(compiled),
        "collectives": parse_collectives(text),
        "compile_s": dt,
    }
    return compiled, info, dt


# ---------------------------------------------------------------------------
# per-cell dry-run
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, multi_pod: bool,
             skip_probes: bool = False,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    import dataclasses as dc

    cfg = get_config(arch)
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    api = ModelApi(cfg)
    sh = SHAPES[shape]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "kind": sh.kind, "seq_len": sh.seq_len, "global_batch": sh.global_batch,
        "param_count": api.param_count(),
        "active_param_count": api.active_param_count(),
        "superlayer_repeat": cfg.superlayer_repeat,
        "blocks_per_superlayer": len(cfg.block_pattern),
        "grad_accum": cfg.grad_accum if sh.kind == "train" else 1,
        "n_enc_layers": cfg.n_enc_layers,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
    }
    if not api.supports(shape):
        result["status"] = "skipped"
        result["skip_reason"] = ("full-attention architecture: 500k dense "
                                 "decode out of scope (DESIGN.md §3)")
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh,
                       seq_shard=cfg.seq_shard_activations
                       and sh.kind in ("train", "prefill"),
                       ws_decode=cfg.weight_stationary_decode
                       and sh.kind == "decode")
    n_dev = mesh.size
    result["seq_shard"] = rules.seq_shard
    result["ws_decode"] = rules.ws_decode
    result["decode_loop"] = cfg.decode_loop

    with use_rules(rules):
        params_abs = api.abstract_params()
        params_specs = api.param_pspecs()
        params_sh = tree_shardings(mesh, params_specs, params_abs)
        batch_abs = api.input_specs(shape)
        batch_specs = psp.batch_specs(batch_abs)
        batch_sh = tree_shardings(mesh, batch_specs, batch_abs)

        if sh.kind == "train":
            # microbatches must still cover every DP replica
            dp = mesh.size // mesh.shape.get("model", 1)
            accum = max(1, min(cfg.grad_accum, sh.global_batch // dp))
            result["grad_accum"] = accum
            optimizer = default_optimizer(cfg)
            opt_abs = jax.eval_shape(optimizer.init, params_abs)
            opt_specs = optimizer.state_pspecs(params_specs)
            opt_sh = tree_shardings(mesh, opt_specs, opt_abs)
            step = build_train_step(api, optimizer, accum=accum)
            with mesh:
                compiled, info, _ = _compile(
                    step, (params_sh, opt_sh, batch_sh),
                    (params_abs, opt_abs, batch_abs), donate=(0, 1))
            result["full"] = info
            with use_rules(rules):
                if not skip_probes and not cfg.is_encdec:
                    result["probe"] = _train_probe(api, mesh, rules, params_abs,
                                                   params_specs, sh, accum)
                elif not skip_probes:
                    result["probe"] = _encdec_train_probe(
                        api, mesh, rules, params_abs, params_specs, sh, accum)
        elif sh.kind == "prefill":
            step = build_prefill_step(api)
            with mesh:
                compiled, info, _ = _compile(step, (params_sh, batch_sh),
                                             (params_abs, batch_abs))
            result["full"] = info
            if not skip_probes:
                result["probe"] = _serve_probe(api, mesh, rules, params_abs,
                                               params_specs, sh, "prefill")
        else:  # decode
            caches_abs = api.cache_shapes(shape)
            cache_specs = api.cache_pspecs(shape)
            caches_sh = tree_shardings(mesh, cache_specs, caches_abs)
            pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
            pos_sh = NamedSharding(mesh, P())
            step = build_decode_step(api)
            with mesh:
                compiled, info, _ = _compile(
                    step, (params_sh, caches_sh, pos_sh, batch_sh),
                    (params_abs, caches_abs, pos_abs, batch_abs), donate=(1,))
            result["full"] = info
            if not skip_probes:
                result["probe"] = _serve_probe(api, mesh, rules, params_abs,
                                               params_specs, sh, "decode",
                                               caches_abs, cache_specs)

        result["status"] = "ok"
        result["devices"] = n_dev
        result["totals"] = scale_totals(result)
        return result


def _train_probe(api, mesh, rules, params_abs, params_specs, sh, accum):
    """Compile grad through one superlayer on one microbatch."""
    cfg = api.cfg
    b_micro = sh.global_batch // accum
    layer_abs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                             params_abs["layers"])
    strip = lambda s: P(*tuple(s)[1:])
    layer_specs = jax.tree.map(strip, params_specs["layers"],
                               is_leaf=lambda x: isinstance(x, P))
    layer_sh = tree_shardings(mesh, layer_specs, layer_abs)
    x_abs = jax.ShapeDtypeStruct((b_micro, sh.seq_len, cfg.d_model),
                                 cfg.compute_dtype)
    x_sh = NamedSharding(mesh, sanitize_spec(rules.spec("act_btd"),
                                             x_abs.shape, mesh))
    hd2 = cfg.resolved_head_dim // 2
    cs_abs = jax.ShapeDtypeStruct((sh.seq_len, hd2), jnp.float32)
    cs_sh = NamedSharding(mesh, P())
    shared = params_abs.get("shared")
    probe = probes.train_body_fn(api)
    if shared is not None:
        shared_sh = tree_shardings(mesh, api.param_pspecs()["shared"], shared)
        args = (layer_abs, shared, x_abs, cs_abs, cs_abs)
        shardings = (layer_sh, shared_sh, x_sh, cs_sh, cs_sh)
        fn = probe
    else:
        fn = lambda lp, x, c, s: probe(lp, None, x, c, s)
        args = (layer_abs, x_abs, cs_abs, cs_abs)
        shardings = (layer_sh, x_sh, cs_sh, cs_sh)
    with mesh:
        _, info, _ = _compile(fn, shardings, args)
    return info


def _encdec_train_probe(api, mesh, rules, params_abs, params_specs, sh, accum):
    cfg = api.cfg
    b_micro = sh.global_batch // accum
    enc_probe, dec_probe = probes.encdec_train_bodies(api)
    strip = lambda s: P(*tuple(s)[1:])
    out = {}
    for name, key, fn in (("enc", "enc_layers", enc_probe),
                          ("dec", "dec_layers", dec_probe)):
        layer_abs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                                 params_abs[key])
        layer_specs = jax.tree.map(strip, params_specs[key],
                                   is_leaf=lambda x: isinstance(x, P))
        layer_sh = tree_shardings(mesh, layer_specs, layer_abs)
        s_len = sh.seq_len if name == "enc" else min(sh.seq_len // 4,
                                                     cfg.max_target_len * 32)
        x_abs = jax.ShapeDtypeStruct((b_micro, s_len, cfg.d_model),
                                     cfg.compute_dtype)
        x_sh = NamedSharding(mesh, sanitize_spec(P(("pod", "data"), None, None),
                                                 x_abs.shape, mesh))
        hd2 = cfg.resolved_head_dim // 2
        cs_abs = jax.ShapeDtypeStruct((s_len, hd2), jnp.float32)
        cs_sh = NamedSharding(mesh, P())
        if name == "enc":
            args = (layer_abs, x_abs, cs_abs, cs_abs)
            shardings = (layer_sh, x_sh, cs_sh, cs_sh)
        else:
            eo_abs = jax.ShapeDtypeStruct((b_micro, sh.seq_len, cfg.d_model),
                                          cfg.compute_dtype)
            eo_sh = NamedSharding(mesh, sanitize_spec(
                P(("pod", "data"), None, None), eo_abs.shape, mesh))
            args = (layer_abs, x_abs, eo_abs, cs_abs, cs_abs)
            shardings = (layer_sh, x_sh, eo_sh, cs_sh, cs_sh)
        with mesh:
            _, info, _ = _compile(fn, shardings, args)
        out[name] = info
    return out


def _serve_probe(api, mesh, rules, params_abs, params_specs, sh, mode,
                 caches_abs=None, cache_specs=None):
    """Compile one superlayer serving body with identical shardings."""
    cfg = api.cfg
    strip = lambda s: P(*tuple(s)[1:])
    hd2 = max(1, cfg.resolved_head_dim // 2)
    cs_sh = NamedSharding(mesh, P())

    if cfg.is_encdec:
        if mode == "prefill":
            enc_probe, dec_probe = probes.encdec_prefill_bodies(api)
            out = {}
            for name, key in (("enc", "enc_layers"), ("dec", "dec_layers")):
                layer_abs = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                    params_abs[key])
                layer_sh = tree_shardings(
                    mesh, jax.tree.map(strip, params_specs[key],
                                       is_leaf=lambda x: isinstance(x, P)),
                    layer_abs)
                s_len = sh.seq_len if name == "enc" else min(sh.seq_len // 4, 1024)
                x_abs = jax.ShapeDtypeStruct((sh.global_batch, s_len, cfg.d_model),
                                             cfg.compute_dtype)
                x_sh = NamedSharding(mesh, sanitize_spec(
                    P(("pod", "data"), None, None), x_abs.shape, mesh))
                cs_abs = jax.ShapeDtypeStruct((s_len, hd2), jnp.float32)
                if name == "enc":
                    with mesh:
                        _, info, _ = _compile(enc_probe,
                                              (layer_sh, x_sh, cs_sh, cs_sh),
                                              (layer_abs, x_abs, cs_abs, cs_abs))
                else:
                    eo_abs = jax.ShapeDtypeStruct(
                        (sh.global_batch, sh.seq_len, cfg.d_model), cfg.compute_dtype)
                    eo_sh = NamedSharding(mesh, sanitize_spec(
                        P(("pod", "data"), None, None), eo_abs.shape, mesh))
                    with mesh:
                        _, info, _ = _compile(dec_probe,
                                              (layer_sh, x_sh, eo_sh, cs_sh, cs_sh),
                                              (layer_abs, x_abs, eo_abs, cs_abs, cs_abs))
                out[name] = info
            return out
        # enc-dec decode
        probe = probes.encdec_dec_decode_body(api)
        layer_abs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                                 params_abs["dec_layers"])
        layer_sh = tree_shardings(
            mesh, jax.tree.map(strip, params_specs["dec_layers"],
                               is_leaf=lambda x: isinstance(x, P)), layer_abs)
        cache1_abs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                                  caches_abs)
        cache1_sh = tree_shardings(
            mesh, jax.tree.map(strip, cache_specs,
                               is_leaf=lambda x: isinstance(x, P)), cache1_abs)
        b = sh.global_batch
        x_abs = jax.ShapeDtypeStruct((b, cfg.d_model), cfg.compute_dtype)
        x_sh = NamedSharding(mesh, sanitize_spec(P(("pod", "data"), None),
                                                 x_abs.shape, mesh))
        i_abs = jax.ShapeDtypeStruct((), jnp.int32)
        l_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
        l_sh = NamedSharding(mesh, sanitize_spec(P(("pod", "data")),
                                                 l_abs.shape, mesh))
        max_pos = cache1_abs["k"].shape[2]
        cs_abs = jax.ShapeDtypeStruct((max_pos, hd2), jnp.float32)
        with mesh:
            _, info, _ = _compile(
                probe,
                (layer_sh, x_sh, cache1_sh, NamedSharding(mesh, P()), l_sh,
                 l_sh, cs_sh, cs_sh),
                (layer_abs, x_abs, cache1_abs, i_abs, l_abs, l_abs, cs_abs,
                 cs_abs))
        return info

    layer_abs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                             params_abs["layers"])
    layer_sh = tree_shardings(
        mesh, jax.tree.map(strip, params_specs["layers"],
                           is_leaf=lambda x: isinstance(x, P)), layer_abs)
    shared = params_abs.get("shared")
    shared_sh = (tree_shardings(mesh, api.param_pspecs()["shared"], shared)
                 if shared is not None else None)

    if mode == "prefill":
        probe = probes.prefill_body_fn(api, max_len=sh.seq_len)
        x_abs = jax.ShapeDtypeStruct((sh.global_batch, sh.seq_len, cfg.d_model),
                                     cfg.compute_dtype)
        x_sh = NamedSharding(mesh, sanitize_spec(rules.spec("act_btd"),
                                                 x_abs.shape, mesh))
        cs_abs = jax.ShapeDtypeStruct((sh.seq_len, hd2), jnp.float32)
        if shared is not None:
            args = (layer_abs, shared, x_abs, cs_abs, cs_abs)
            shardings = (layer_sh, shared_sh, x_sh, cs_sh, cs_sh)
            fn = probe
        else:
            fn = lambda lp, x, c, s: probe(lp, None, x, c, s)
            args = (layer_abs, x_abs, cs_abs, cs_abs)
            shardings = (layer_sh, x_sh, cs_sh, cs_sh)
        with mesh:
            _, info, _ = _compile(fn, shardings, args)
        return info

    # decode
    probe = probes.decode_body_fn(api)
    cache1_abs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                              caches_abs)
    cache1_sh = tree_shardings(
        mesh, jax.tree.map(strip, cache_specs,
                           is_leaf=lambda x: isinstance(x, P)), cache1_abs)
    b = sh.global_batch
    x_abs = jax.ShapeDtypeStruct((b, cfg.d_model), cfg.compute_dtype)
    x_sh = NamedSharding(mesh, sanitize_spec(P(("pod", "data"), None),
                                             x_abs.shape, mesh))
    i_abs = jax.ShapeDtypeStruct((), jnp.int32)
    l_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
    l_sh = NamedSharding(mesh, sanitize_spec(P(("pod", "data")), l_abs.shape, mesh))
    cs_abs = jax.ShapeDtypeStruct((sh.seq_len, hd2), jnp.float32)
    if shared is not None:
        fn = probe
        args = (layer_abs, shared, x_abs, cache1_abs, cs_abs, cs_abs, i_abs, l_abs)
        shardings = (layer_sh, shared_sh, x_sh, cache1_sh, cs_sh, cs_sh,
                     NamedSharding(mesh, P()), l_sh)
    else:
        fn = lambda lp, x, st, c, s, p_, kl: probe(lp, None, x, st, c, s, p_, kl)
        args = (layer_abs, x_abs, cache1_abs, cs_abs, cs_abs, i_abs, l_abs)
        shardings = (layer_sh, x_sh, cache1_sh, cs_sh, cs_sh,
                     NamedSharding(mesh, P()), l_sh)
    with mesh:
        _, info, _ = _compile(fn, shardings, args)
    return info


def scale_totals(result: Dict[str, Any]) -> Dict[str, float]:
    """full + (repeats-1) x probe, x accum for training (DESIGN.md §5)."""
    full = result["full"]
    kind = result["kind"]
    repeat = result["superlayer_repeat"]
    accum = result.get("grad_accum", 1)
    probe = result.get("probe")

    def add(a, b, scale):
        return {k: a[k] + scale * b[k] for k in ("flops", "bytes")}

    totals = dict(full["cost"])
    coll = float(full["collectives"]["total_bytes"])
    train = kind == "train"
    if probe is not None and "cost" in probe:          # decoder-only (any kind)
        totals = add(totals, probe["cost"], repeat - 1)
        coll += (repeat - 1) * probe["collectives"]["total_bytes"]
    elif probe is not None:                             # enc-dec (train/prefill)
        n_enc = result.get("n_enc_layers", 0)
        totals = add(totals, probe["enc"]["cost"], max(0, n_enc - 1))
        totals = add(totals, probe["dec"]["cost"], repeat - 1)
        coll += (max(0, n_enc - 1) * probe["enc"]["collectives"]["total_bytes"]
                 + (repeat - 1) * probe["dec"]["collectives"]["total_bytes"])
    if train:
        totals = {k: v * accum for k, v in totals.items()}
        coll *= accum
    totals["collective_bytes"] = coll
    return totals


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    res = run_cell(args.arch, args.shape, args.multi_pod,
                   skip_probes=args.skip_probes)
    name = f"{args.arch}__{args.shape}__{res['mesh']}.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    if res["status"] == "ok":
        mem = res["full"]["memory"]
        print(f"{args.arch} x {args.shape} x {res['mesh']}: OK  "
              f"peak/dev={mem['peak_estimate_bytes']/2**30:.2f} GiB  "
              f"flops/dev={res['totals']['flops']:.3e}  "
              f"coll/dev={res['totals']['collective_bytes']:.3e} B  "
              f"compile={res['full']['compile_s']:.1f}s")
        print("memory_analysis:", {k: round(v / 2**20, 1)
                                   for k, v in mem.items()}, "MiB")
        print("cost_analysis:", res["full"]["cost"])
    else:
        print(f"{args.arch} x {args.shape}: SKIPPED ({res['skip_reason']})")


if __name__ == "__main__":
    main()
