"""Parameter PartitionSpec trees (TP over `model`, FSDP over `data`).

Every 2-D weight is sharded on both mesh axes: the "parallel" dim (heads /
ffn hidden / vocab / experts) over `model` (Megatron TP) and the other dim
over `data` (FSDP — XLA all-gathers the layer's weights just-in-time inside
the scan body, which is ZeRO-3 behavior). Axes that do not divide are dropped
per-array by ``sanitize_spec`` at lowering time, so these trees are safe for
every architecture.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

# FSDP axis spans all data-parallel replicas (pod x data); `pod` is dropped
# automatically on the single-pod mesh. TP axis is `model`.
D, M = ("pod", "data"), "model"


def _attn_specs(cfg: ModelConfig) -> Dict[str, P]:
    s = {"wq": P(D, M), "wk": P(D, M), "wv": P(D, M), "wo": P(M, D)}
    if cfg.qkv_bias:
        s.update({"bq": P(M), "bk": P(M), "bv": P(M)})
    return s


def _mlp_specs() -> Dict[str, P]:
    return {"gate": P(D, M), "up": P(D, M), "down": P(M, D)}


def _block_specs(kind: str, cfg: ModelConfig) -> Dict[str, Any]:
    if kind in ("dense", "shared_attn"):
        return {"norm1": P(None), "attn": _attn_specs(cfg),
                "norm2": P(None), "mlp": _mlp_specs()}
    if kind == "moe":
        return {"norm1": P(None), "attn": _attn_specs(cfg), "norm2": P(None),
                "moe": {"router": P(None, None),
                        "gate": P(M, D, None), "up": P(M, D, None),
                        "down": P(M, None, D)}}
    if kind == "mamba":
        return {"norm": P(None),
                "mamba": {"in_proj": P(D, M), "conv_w": P(None, M),
                          "conv_b": P(M), "a_log": P(None), "dt_bias": P(None),
                          "d_skip": P(None), "out_proj": P(M, D),
                          "norm_w": P(None)}}
    if kind == "mlstm":
        return {"norm": P(None),
                "mlstm": {"up": P(D, M), "wqkv": P(D, M), "wgates": P(D, None),
                          "gate_b": P(None), "down": P(M, D),
                          "norm_w": P(None)}}
    if kind == "slstm":
        return {"norm": P(None),
                "slstm": {"wx": P(D, M), "r": P(None, None, None),
                          "b": P(None), "out": P(None, D), "norm_w": P(None)}}
    raise ValueError(kind)


def _stack(tree):
    """Prefix specs with the scan (superlayer) dim."""
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lm_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    layers = {f"b{i}": _block_specs(kind, cfg)
              for i, kind in enumerate(cfg.block_pattern)
              if kind != "shared_attn"}
    specs: Dict[str, Any] = {
        "embed": P(M, D),
        "layers": _stack(layers),
        "final_norm": P(None),
    }
    if "shared_attn" in cfg.block_pattern:
        specs["shared"] = _block_specs("shared_attn", cfg)
    if not cfg.tie_embeddings:
        specs["head"] = P(D, M)
    return specs


def encdec_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    enc = {"norm1": P(None), "attn": _attn_specs(cfg),
           "norm2": P(None), "mlp": _mlp_specs()}
    dec = {"norm1": P(None), "self_attn": _attn_specs(cfg),
           "norm_c": P(None), "cross_attn": _attn_specs(cfg),
           "norm2": P(None), "mlp": _mlp_specs()}
    return {
        "embed": P(M, D),
        "enc_layers": _stack(enc),
        "dec_layers": _stack(dec),
        "enc_norm": P(None),
        "final_norm": P(None),
        "head": P(D, M),
    }


BATCH = ("pod", "data")


def batch_specs(batch: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, P]:
    out = {}
    for k, v in batch.items():
        out[k] = P(*((BATCH,) + (None,) * (len(v.shape) - 1)))
    return out


def cache_specs(shapes) -> Any:
    """Serving-state specs: (R, B, heads/KH, seq, ...) — KV seq over model."""
    def spec(s: jax.ShapeDtypeStruct) -> P:
        if len(s.shape) == 5:                  # (R, B, KH, S, hd) kv cache
            return P(None, BATCH, None, M, None)
        if len(s.shape) == 4:                  # (R, B, H, state) ssm-ish
            return P(None, BATCH, M, None)
        if len(s.shape) == 3:
            return P(None, BATCH, None)
        return P(*((None,) * len(s.shape)))

    def spec5(s):
        if len(s.shape) == 5 and s.shape[3] > s.shape[2]:
            return P(None, BATCH, None, M, None)
        if len(s.shape) == 5:                  # (R, B, H, dk, dv) gla state
            return P(None, BATCH, M, None, None)
        return spec(s)

    return jax.tree.map(spec5, shapes)
