"""Post-join enrichment/ranking: a model-scored delivery budget per channel.

The paper's "early result filtering" (§4) trims results *structurally*
(indexes, watermarks) before broker fan-out; this module makes the last
trim *learned*. An ``EnrichmentStage`` plugs into the fused tick as a
post-join, pre-delivery hook: after the execution join produces each
plan-group's stacked pair grid and BEFORE ``broker.deliver_all`` packs it,
the stage scores every candidate record in ONE batched call and the
lowest-scoring pairs past a per-channel delivery budget are dropped —
inside the same jitted call as discovery, join, and delivery, so the hook
adds no host sync and composes with the pipelined runtime and the sharded
engine (scores are shard-local; the budget applies per shard, i.e. per
device delivery capacity, like every other cap).

Contract (asserted by tests/test_enrich.py):

  * scoring granularity is the CANDIDATE RECORD: one ``score`` call per
    (channel, candidate-row) slot of the stacked result — the same slots
    the compacted CSR stream scatters back into — and every pair of a slot
    inherits its score. ``payload_tokens`` is the record's field vector
    (the out-of-band token payload proxy), ``channel_ids`` the global
    channel rows, ``sids`` the stable record row ids.
  * under-budget channels are BIT-IDENTICAL to the scorer-less engine:
    when a channel's produced pairs fit its budget the pruned mask equals
    the original validity mask, so the FusedDelivery (wire bytes, spill
    streams, ring state, stats) is unchanged byte for byte.
  * over-budget channels keep the TOP-``budget`` pairs by (score desc,
    ravel position asc) — ties resolve to the earlier pair, making the
    rank deterministic — and deliver them in the usual ravel order. The
    dropped remainder is counted in ``DeliveryStats.ranked_pairs`` /
    ``ranked_sids`` (a subset of ``dropped_*``), preserving
    delivered + spilled + dropped == produced per stage.
  * a stage's ``identity`` keys every plan-keyed engine cache (the engine
    stamps it into ``ChannelPlan.scorer`` at dispatch), so a fixed stage
    retraces nothing at steady state and a swap retraces like a plan
    switch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import records as R
from repro.core.broker import _member_counts
from repro.core.plans import ChannelResult


@runtime_checkable
class EnrichmentStage(Protocol):
    """A batched post-join scorer with a per-channel delivery budget.

    ``score`` must be pure and jit-compatible: it runs INSIDE the engine's
    fused tick call. ``budget`` (static python int) caps delivered pairs
    per channel per execution; None disables pruning (the stage is then a
    pure tag — scoring is skipped entirely). ``identity`` must be hashable
    and change whenever scoring semantics change: it keys the engine's
    compiled-plan caches."""

    @property
    def budget(self) -> Optional[int]: ...

    @property
    def identity(self) -> tuple: ...

    def score(self, payload_tokens: jnp.ndarray, channel_ids: jnp.ndarray,
              sids: jnp.ndarray) -> jnp.ndarray:
        """(N, F) int32 payload tokens, (N,) int32 channel rows, (N,) int32
        stable record ids -> (N,) float32 relevance scores."""
        ...


@dataclasses.dataclass(frozen=True)
class NoopScorer:
    """Constant scores: with any budget the kept set is the ravel-order
    prefix (stable tie-break), so an under-budget NoopScorer engine is
    bit-identical to a scorer-less one — the parity baseline."""

    budget: Optional[int] = None

    @property
    def identity(self) -> tuple:
        return ("noop", self.budget)

    def score(self, payload_tokens, channel_ids, sids):
        return jnp.zeros(payload_tokens.shape[:1], jnp.float32)


@dataclasses.dataclass(frozen=True)
class HeuristicScorer:
    """Pure-jnp payload scorer (tier-1-testable): a fixed urgency weighting
    over the enriched fields — threat and hate-speech rates dominate,
    weapon/drug flags and retweet reach break ties."""

    budget: Optional[int] = None
    weights: Tuple[float, ...] = (3.0, 2.0, 1.0, 0.5, 1e-3)

    @property
    def identity(self) -> tuple:
        return ("heuristic", self.budget, self.weights)

    def score(self, payload_tokens, channel_ids, sids):
        f = payload_tokens.astype(jnp.float32)
        w = self.weights
        return (w[0] * f[:, R.THREATENING_RATE]
                + w[1] * f[:, R.HATE_SPEECH_RATE]
                + w[2] * f[:, R.WEAPON_MENTIONED]
                + w[3] * f[:, R.DRUG_ACTIVITY]
                + w[4] * f[:, R.RETWEET_COUNT])


class LMScorer:
    """Reduced-LM scorer: one batched prefill (``models/lm.forward`` via
    ``launch/serve.prefill_scores``) over the candidate payload tokens,
    inside the fused tick call. The record's field vector is the prompt
    (clipped into the vocab); the pooled final-position logits are the
    relevance score. Params are initialized once at construction — the
    stage is functionally frozen, so ``identity`` needs only the config
    name, seed, and budget."""

    def __init__(self, cfg=None, params=None, budget: Optional[int] = None,
                 seed: int = 0, lanes: int = 64):
        from repro import configs
        from repro.models.model import ModelApi
        self.cfg = cfg if cfg is not None else configs.get_reduced("qwen2-1.5b")
        self.api = ModelApi(self.cfg)
        self.params = (params if params is not None
                       else self.api.init(jax.random.key(seed)))
        self.budget = budget
        self.seed = seed
        self.lanes = lanes

    @property
    def identity(self) -> tuple:
        return ("lm", self.cfg.name, self.seed, self.lanes, self.budget)

    def score(self, payload_tokens, channel_ids, sids):
        from repro.launch.serve import prefill_scores
        toks = jnp.clip(payload_tokens, 0, self.cfg.vocab_size - 1)
        return prefill_scores(self.params, self.cfg, toks, lanes=self.lanes)


def rank_result(stage: EnrichmentStage, ds, result: ChannelResult,
                channel_rows: jnp.ndarray, group_sids: jnp.ndarray,
                counts: Optional[jnp.ndarray] = None):
    """Score + budget-prune one stacked ChannelResult (pure, jit-compatible).

    Scores the (C, Rm) candidate slots in one batched ``stage.score`` call,
    broadcasts scores to the (C, Rm, maxT) pair grid, and invalidates every
    pair ranked at or past ``stage.budget`` under (score desc, ravel asc).
    Returns ``(pruned_result, ranked_pairs, ranked_sids)`` — the per-channel
    (C,) counts of pruned pairs and their member sIDs (via the same
    member-count pass delivery uses, so sID conservation telescopes).

    When a channel's produced count fits the budget the kept mask equals
    ``pair_valid`` and the result passes through BIT-identically (pair
    rows/targets are already -1-masked at invalid slots by both join
    formulations); ``budget=None`` short-circuits entirely.

    Cost note: because every pair of a slot shares the slot's score and a
    slot's pairs are CONTIGUOUS in ravel order, the (score desc, ravel asc)
    pair rank is computed at SLOT granularity, and only the top
    ``min(budget, Rm)`` slots are ever materialized: every live slot holds
    >= 1 valid pair, so no slot past the top-``budget`` can receive any
    budget (and under-budget channels have <= budget live slots, all
    captured). ``lax.top_k`` breaks score ties toward the LOWER slot index
    — exactly the ravel-order tie-break — then the budget is allocated
    down the ranked slots by cumulative valid-pair count and each
    partially funded slot keeps its first valid pairs in target order.
    Everything else is elementwise, so the hook's overhead is one
    top-``budget`` selection + the ``score`` call per fused tick. Scores
    must be finite (-inf marks pair-less slots internally)."""
    C, Rm, Tm = result.pair_valid.shape
    budget = stage.budget
    if budget is None:
        zeros = jnp.zeros((C,), jnp.int32)
        return result, zeros, zeros
    rows = result.matched_rows                            # (C, Rm), -1 pads
    tokens = ds.fields[jnp.maximum(rows, 0) % ds.capacity]  # (C, Rm, F)
    ch = jnp.broadcast_to(channel_rows[:, None], rows.shape)
    scores = stage.score(tokens.reshape(C * Rm, -1), ch.reshape(-1),
                         rows.reshape(-1))
    scores = jnp.asarray(scores, jnp.float32).reshape(C, Rm)
    valid3 = result.pair_valid
    vc = jnp.sum(valid3.astype(jnp.int32), axis=2)        # (C, Rm)
    masked = jnp.where(vc > 0, scores, -jnp.inf)
    k = min(int(budget), Rm)
    _, idx = jax.lax.top_k(masked, k)                     # (C, k)
    vc_top = jnp.take_along_axis(vc, idx, axis=1)
    before = jnp.cumsum(vc_top, axis=1) - vc_top          # pairs ranked above
    keep_top = jnp.clip(budget - before, 0, vc_top)
    chan = jnp.arange(C, dtype=jnp.int32)[:, None]
    keep_per_slot = jnp.zeros((C, Rm), jnp.int32).at[chan, idx].set(
        keep_top)
    rank_in_slot = jnp.cumsum(valid3.astype(jnp.int32), axis=2) - 1
    keep = valid3 & (rank_in_slot < keep_per_slot[:, :, None])
    pruned2 = (valid3 & ~keep).reshape(C, -1)
    ranked_pairs = jnp.sum(pruned2.astype(jnp.int32), axis=1)
    members = _member_counts(group_sids, pruned2,
                             result.pair_targets.reshape(C, -1), counts)
    ranked_sids = jnp.sum(members, axis=1)
    out = result._replace(
        pair_valid=keep,
        pair_rows=jnp.where(keep, result.pair_rows, -1),
        pair_targets=jnp.where(keep, result.pair_targets, -1))
    return out, ranked_pairs, ranked_sids
