"""Pallas TPU kernel: compacted-stream pair expansion.

Layout: the compacted candidate stream arrives as per-entry gathers — an
(S, maxT) int32 target-slot tile stream plus (S, maxT) member/broker tables
and (S, 1) per-entry scalars (live-target count, validity, payload bytes).
The grid tiles S; each step loads a (TS, maxT) block set into VMEM and emits
the four pair grids (validity bitmap, member counts, wire bytes, broker ids)
with dense elementwise/broadcast compute only — all the gathers happened
upstream at stream assembly, so the kernel body is pure tile math.

VMEM budget per step (TS=256, maxT=64): 3 inputs + 4 outputs of
256*64*4 = 64 KB each plus 3 (TS, 1) columns -> well under 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TS = 256


def _kernel(tgt_ref, tgtn_ref, mem_ref, bid_ref, valid_ref, pay_ref,
            pv_ref, mem_out_ref, bytes_ref, bids_ref, *,
            num_brokers: int, aggregated: bool):
    tgt = tgt_ref[...]                        # (TS, maxT) int32
    tgt_n = tgtn_ref[...]                     # (TS, 1) int32
    mem = mem_ref[...]                        # (TS, maxT) int32
    bid = bid_ref[...]                        # (TS, maxT) int32
    valid = valid_ref[...]                    # (TS, 1) int32 0/1
    pay = pay_ref[...]                        # (TS, 1) int32
    ts, max_t = tgt.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (ts, max_t), 1)
    pv = (valid > 0) & (cols < tgt_n) & (tgt >= 0)
    m = jnp.where(pv, mem, 0)
    per = pay + (4 * m if aggregated else 0)  # (TS, 1) broadcasts over maxT
    pv_ref[...] = pv.astype(jnp.int8)
    mem_out_ref[...] = m
    bytes_ref[...] = jnp.where(pv, per, 0)
    bids_ref[...] = jnp.where(pv, bid, num_brokers)


@functools.partial(jax.jit, static_argnames=("num_brokers", "aggregated",
                                             "ts", "interpret"))
def join_pairs_kernel(tgt: jnp.ndarray, tgt_n: jnp.ndarray,
                      members: jnp.ndarray, brokers: jnp.ndarray,
                      valid: jnp.ndarray, payload: jnp.ndarray,
                      num_brokers: int, aggregated: bool,
                      ts: int = DEFAULT_TS, interpret: bool = True):
    """(S, maxT) gathers + (S,) scalars -> the four (S, maxT) pair grids.

    S must be a multiple of ts (ops.py pads). Returns
    (pair_valid int8, members int32, pair_bytes int32, bids int32).
    """
    s, max_t = tgt.shape
    assert s % ts == 0, (s, ts)
    grid = (s // ts,)
    col = lambda a: a.reshape(s, 1).astype(jnp.int32)
    spec2 = pl.BlockSpec((ts, max_t), lambda i: (i, 0))
    spec1 = pl.BlockSpec((ts, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, num_brokers=num_brokers,
                          aggregated=aggregated),
        grid=grid,
        in_specs=[spec2, spec1, spec2, spec2, spec1, spec1],
        out_specs=[spec2, spec2, spec2, spec2],
        out_shape=[
            jax.ShapeDtypeStruct((s, max_t), jnp.int8),
            jax.ShapeDtypeStruct((s, max_t), jnp.int32),
            jax.ShapeDtypeStruct((s, max_t), jnp.int32),
            jax.ShapeDtypeStruct((s, max_t), jnp.int32),
        ],
        interpret=interpret,
    )(tgt, col(tgt_n), members, brokers, col(valid), col(payload))
