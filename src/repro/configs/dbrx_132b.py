"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base; unverified]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
        vocab_size=100352, head_dim=128, qkv_bias=False, rope_theta=5e5,
        n_experts=16, moe_top_k=4,
        block_pattern=("moe",), superlayer_repeat=40,
        param_dtype=jnp.bfloat16, grad_accum=16, optimizer="adafactor",
        sub_quadratic=False, weight_stationary_decode=True,
    ).validate()
