"""Production train loop: sharded step, checkpointing, watchdog, recovery.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On the CPU container this runs reduced configs end-to-end (the same code path
the TPU deployment uses, minus real pods). XLA collective/compute overlap is
enabled via the latency-hiding scheduler flags below when devices > 1.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.ckpt.manager import CheckpointManager
from repro.data.synthetic import TokenStream
from repro.launch.steps import build_train_step, default_optimizer
from repro.models.model import ModelApi
from repro.runtime.failure import FailureInjector, StepTimer

XLA_OVERLAP_FLAGS = ("--xla_tpu_enable_latency_hiding_scheduler=true "
                     "--xla_tpu_enable_async_collective_fusion=true")


def make_batch_fn(cfg, batch: int, seq: int):
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=seq,
                         global_batch=batch)

    def fn(step: int):
        b = stream.batch(step)
        if cfg.frontend == "embed" or cfg.is_encdec:
            rng = np.random.default_rng(step)
            if cfg.is_encdec:
                s_dec = max(4, seq // 4)
                return {"embeds": rng.normal(size=(batch, seq, cfg.d_model))
                        .astype(np.float32),
                        "tokens": b["tokens"][:, :s_dec],
                        "labels": b["labels"][:, :s_dec]}
            return {"embeds": rng.normal(size=(batch, seq, cfg.d_model))
                    .astype(np.float32), "labels": b["labels"]}
        return b

    return fn


def train(cfg, steps: int, batch: int, seq: int, ckpt_dir: str,
          ckpt_every: int = 20, injector: FailureInjector = None,
          log_every: int = 10, resume: bool = True):
    api = ModelApi(cfg)
    optimizer = default_optimizer(cfg)
    step_fn = jax.jit(build_train_step(api, optimizer,
                                       accum=min(cfg.grad_accum, batch)),
                      donate_argnums=(0, 1))
    mgr = CheckpointManager(ckpt_dir, keep=2)
    batch_fn = make_batch_fn(cfg, batch, seq)
    timer = StepTimer()

    params = api.init(jax.random.key(0))
    opt_state = optimizer.init(params)
    start = 0
    latest = mgr.latest_step()
    if resume and latest is not None:
        state = mgr.restore(latest, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = latest
    losses = []
    try:
        for step in range(start, steps):
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch_fn(step))
            jax.block_until_ready(metrics["loss"])
            timer.record("host0", time.perf_counter() - t0)
            losses.append(float(metrics["loss"]))
            if (step + 1) % ckpt_every == 0 or step + 1 == steps:
                mgr.save(step + 1, {"params": params, "opt": opt_state})
            if (step + 1) % log_every == 0:
                print(f"step {step+1}: loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={timer.times['host0']*1e3:.0f}ms", flush=True)
    finally:
        # Flush the async writer even when a step fails: the last published
        # checkpoint must be durable (not a half-renamed .tmp) so a restart
        # actually resumes from it.
        mgr.wait()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    t0 = time.time()
    _, _, losses = train(cfg, args.steps, args.batch, args.seq, args.ckpt_dir)
    print(f"done in {time.time()-t0:.1f}s; loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
