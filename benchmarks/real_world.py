"""Fig. 21: real-world-style trending-tweet channels (language-skewed stream).

English dominates the stream (~62%) and Portuguese is rarer (~18%), so the
Portuguese channel's fixed conjunction is more selective and the BAD index
wins more — the paper's headline 62%/70% execution-time reductions.
"""
from __future__ import annotations

import numpy as np

from repro.core import records as R
from repro.core.channel import trending_tweets_in_country
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import tweet_batch
from benchmarks.common import emit, exec_time, scale


def run(rng) -> None:
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 15,
                    max_window=1 << 15, max_candidates=1 << 14,
                    group_cap=1024)
    eng.create_channel(trending_tweets_in_country(0, "EnglishTrending"))
    eng.create_channel(trending_tweets_in_country(1, "PortugueseTrending"))
    n_subs = scale(30_000, 2048)
    countries = rng.integers(0, 200, n_subs).astype(np.int32)
    eng.subscribe_bulk("EnglishTrending", countries, np.zeros(n_subs, np.int32))
    eng.subscribe_bulk("PortugueseTrending", countries, np.zeros(n_subs, np.int32))
    b = tweet_batch(rng, scale(24_576, 2048), t0=100)
    f = np.asarray(b.fields).copy()
    f[:, R.RETWEET_COUNT] = np.where(rng.random(f.shape[0]) < 0.05,
                                     rng.integers(100_001, 5_000_000, f.shape[0]),
                                     rng.integers(0, 100_001, f.shape[0]))
    eng.ingest(R.RecordBatch.from_numpy(f, np.asarray(b.location)))

    for chan in ("EnglishTrending", "PortugueseTrending"):
        t_base, i_b = exec_time(eng, chan, ExecutionFlags(scan_mode="trad_index"))
        t_full, i_f = exec_time(eng, chan, ExecutionFlags.fully_optimized())
        assert i_b["notified"] == i_f["notified"]
        red = 100 * (1 - t_full / max(t_base, 1e-9))
        emit(f"fig21/{chan}/baseline_trad_index", t_base,
             f"candidates={i_b['scanned']}")
        emit(f"fig21/{chan}/fully_optimized", t_full,
             f"reduction={red:.0f}% (paper: 62-70%)")


if __name__ == "__main__":
    run(np.random.default_rng(0))
