"""TPU-substrate kernel microbenchmarks (CPU wall time; interpret-mode Pallas
is a correctness artifact, not a speed artifact — the TPU perf story lives in
EXPERIMENTS.md §Roofline). Reports kernel-vs-oracle parity cost and the
ingest-path throughput of the jnp predicate evaluator the engine actually
uses on CPU."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.predicates import Predicate, compile_conditions, evaluate_conditions
from repro.kernels.predicate_filter import ops as pf_ops
from repro.kernels.spatial_match import ref as sm_ref
from benchmarks.common import emit, scale, timeit


def run(rng) -> None:
    n = scale(16_384, 2048)
    fields = jnp.asarray(rng.integers(0, 100, (n, 10)).astype(np.int32))
    chans = [[Predicate.parse(3, "==", 10), Predicate.parse(6, "==", 3)],
             [Predicate.parse(3, "==", 10)],
             [Predicate.parse(1, "==", 0), Predicate.parse(2, ">", 10_000),
              Predicate.parse(4, ">", 5)]]
    conds = compile_conditions(chans)
    t_ref = timeit(lambda: evaluate_conditions(fields, conds))
    emit("kernels/conditions_eval_jnp_16k", t_ref,
         f"records_per_s={n/max(t_ref,1e-9):.2e}")
    t_canon = timeit(lambda: pf_ops.predicate_filter_ref(fields, conds))
    emit("kernels/conditions_eval_interval_16k", t_canon,
         f"records_per_s={n/max(t_canon,1e-9):.2e}")

    t = jnp.asarray((rng.normal(size=(1024, 2)) * 30).astype(np.float32))
    u = jnp.asarray((rng.normal(size=(8192, 2)) * 30).astype(np.float32))
    t_sm = timeit(lambda: sm_ref.spatial_match(t, u, 10.0))
    emit("kernels/spatial_match_1kx8k", t_sm,
         f"pairs_per_s={1024*8192/max(t_sm,1e-9):.2e}")


if __name__ == "__main__":
    run(np.random.default_rng(0))
