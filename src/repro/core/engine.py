"""BADEngine: the host-side orchestrator tying the data plane together.

Responsibilities (paper Fig. 1): data feed ingestion -> ActiveDataset append +
conditionsList evaluation + BAD-index maintenance; channel execution under a
chosen ``ExecutionFlags`` plan; broker accounting; subscription control plane
(Algorithm 1 grouping + UserParameters upkeep).

The engine is deliberately a thin host shell: every per-record code path is a
jitted pure function over fixed-shape arrays.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bad_index as bidx
from repro.core import plans
from repro.core import records as R
from repro.core import subscriptions as subs
from repro.core.broker import BrokerRegistry
from repro.core.channel import ChannelSpec
from repro.core.predicates import (CompiledConditions, compile_conditions,
                                   evaluate_conditions)
from repro.core.user_params import UserParameters


@dataclasses.dataclass
class ChannelState:
    spec: ChannelSpec
    index: int                      # row in the stacked conditionsList / BADIndexState
    aggregator: subs.Aggregator
    user_params: UserParameters
    last_exec_ts: int = 0
    last_exec_size: int = 0
    executions: int = 0
    # caches invalidated on subscription changes
    _targets_flat: Optional[plans.TargetArrays] = None
    _targets_grouped: Optional[plans.TargetArrays] = None
    _groups: Optional[subs.SubscriptionGroups] = None
    _flat: Optional[subs.SubscriptionTable] = None


@dataclasses.dataclass
class ExecutionReport:
    channel: str
    flags: plans.ExecutionFlags
    result: plans.ChannelResult
    wall_time_s: float
    num_results: int
    num_notified: int
    scanned: int
    broker_bytes: np.ndarray


class BADEngine:
    def __init__(self,
                 dataset_capacity: int = 1 << 18,
                 index_capacity: int = 1 << 15,
                 max_window: int = 1 << 15,
                 max_candidates: int = 1 << 13,
                 frame_bytes: int = 40 * 1024,
                 schema: R.Schema = R.ENRICHED_TWEET_SCHEMA,
                 brokers: Tuple[str, ...] = ("BrokerA",),
                 use_pallas: bool = False,
                 group_cap: Optional[int] = None):
        self.schema = schema
        self.dataset = R.ActiveDataset.create(dataset_capacity, schema)
        self.index_capacity = index_capacity
        self.max_window = max_window
        self.max_candidates = max_candidates
        self.frame_bytes = frame_bytes
        self.group_cap = group_cap or subs.cap_from_frame_bytes(frame_bytes)
        self.brokers = BrokerRegistry.create(*brokers)
        self.channels: Dict[str, ChannelState] = {}
        self.use_pallas = use_pallas
        self.user_locations = jnp.zeros((1, 2), dtype=jnp.float32)
        self.user_brokers = jnp.zeros((1,), dtype=jnp.int32)
        self.now = 0
        self._conds: Optional[CompiledConditions] = None
        self.index_state = bidx.BADIndexState.create(0, index_capacity)
        self._ingest_fn = None

    # ------------------------------------------------------------------
    # control plane
    # ------------------------------------------------------------------

    def create_channel(self, spec: ChannelSpec) -> None:
        if spec.name in self.channels:
            raise ValueError(f"channel {spec.name} exists")
        if self.dataset.size.item() > 0 and spec.fixed_preds:
            # BAD indexes only see records ingested after channel creation —
            # same semantics as the paper (continuous queries over new data).
            pass
        st = ChannelState(
            spec=spec,
            index=len(self.channels),
            aggregator=subs.Aggregator(self.group_cap),
            user_params=UserParameters.create(spec.param_domain),
            last_exec_ts=self.now,
        )
        st.last_exec_size = int(self.dataset.size)
        self.channels[spec.name] = st
        self._rebuild_conditions()

    def drop_channel(self, name: str) -> None:
        del self.channels[name]
        for i, st in enumerate(self.channels.values()):
            st.index = i
        self._rebuild_conditions()

    def subscribe(self, channel: str, param: int, broker: str = "BrokerA",
                  sid: Optional[int] = None) -> int:
        st = self.channels[channel]
        bid = self.brokers.names[broker]
        sid = st.aggregator.add_subscription(param, bid, sid)
        st.user_params.add(param)
        st._targets_flat = st._targets_grouped = st._groups = st._flat = None
        return sid

    def subscribe_bulk(self, channel: str, params: np.ndarray,
                       brokers: np.ndarray) -> None:
        """Bulk control-plane load (still Algorithm-1 semantics via replay)."""
        st = self.channels[channel]
        for p, b in zip(np.asarray(params).tolist(), np.asarray(brokers).tolist()):
            st.aggregator.add_subscription(p, b)
            st.user_params.add(p)
        st._targets_flat = st._targets_grouped = st._groups = st._flat = None

    def unsubscribe(self, channel: str, param: int, broker: str, sid: int) -> bool:
        st = self.channels[channel]
        ok = st.aggregator.remove_subscription(param, self.brokers.names[broker], sid)
        if ok:
            st.user_params.remove(param)
            st._targets_flat = st._targets_grouped = st._groups = st._flat = None
        return ok

    def set_user_locations(self, locations: np.ndarray,
                           brokers: Optional[np.ndarray] = None) -> None:
        self.user_locations = jnp.asarray(locations, dtype=jnp.float32)
        if brokers is None:
            brokers = np.zeros((locations.shape[0],), dtype=np.int32)
        self.user_brokers = jnp.asarray(brokers, dtype=jnp.int32)

    # ------------------------------------------------------------------
    # data plane: ingestion
    # ------------------------------------------------------------------

    def _rebuild_conditions(self) -> None:
        specs = sorted(self.channels.values(), key=lambda s: s.index)
        self._conds = compile_conditions([list(s.spec.fixed_preds) for s in specs])
        old = self.index_state
        new = bidx.BADIndexState.create(len(specs), self.index_capacity)
        n_keep = min(old.num_channels, new.num_channels)
        if n_keep:
            new = bidx.BADIndexState(
                new.row_ids.at[:n_keep].set(old.row_ids[:n_keep]),
                new.counts.at[:n_keep].set(old.counts[:n_keep]),
                new.watermarks.at[:n_keep].set(old.watermarks[:n_keep]),
                new.overflowed.at[:n_keep].set(old.overflowed[:n_keep]),
            )
        self.index_state = new
        self._ingest_fn = None  # shapes changed; re-trace

    def _build_ingest(self):
        conds = self._conds
        use_pallas = self.use_pallas

        @jax.jit
        def ingest_step(ds, index_state, batch):
            ds, row_ids = _append(ds, batch)
            if use_pallas:
                from repro.kernels.predicate_filter import ops as pf_ops
                matches = pf_ops.predicate_filter(batch.fields, conds)
            else:
                matches = evaluate_conditions(batch.fields, conds)
            index_state = _insert(index_state, row_ids, matches)
            return ds, index_state, row_ids

        return ingest_step

    def ingest(self, batch: R.RecordBatch) -> np.ndarray:
        """Data feed entry point: append + BAD-index maintenance (Algorithm 2)."""
        if self._ingest_fn is None:
            self._ingest_fn = self._build_ingest()
        self.dataset, self.index_state, row_ids = self._ingest_fn(
            self.dataset, self.index_state, batch)
        ts = batch.fields[:, R.TIMESTAMP]
        self.now = max(self.now, int(jnp.max(ts))) if batch.num_records else self.now
        return np.asarray(row_ids)

    # ------------------------------------------------------------------
    # data plane: channel execution
    # ------------------------------------------------------------------

    def _targets(self, st: ChannelState, aggregated: bool) -> plans.TargetArrays:
        if aggregated:
            if st._targets_grouped is None:
                groups = st.aggregator.build()
                st._groups = groups
                by_param, by_count = subs.param_to_targets(
                    groups.group_params, st.spec.param_domain)
                st._targets_grouped = plans.TargetArrays(
                    jnp.asarray(groups.group_params), jnp.asarray(groups.group_brokers),
                    jnp.asarray(groups.group_counts), jnp.asarray(by_param),
                    jnp.asarray(by_count))
            return st._targets_grouped
        if st._targets_flat is None:
            flat = self._flat_table(st)
            by_param, by_count = subs.param_to_targets(flat.params, st.spec.param_domain)
            st._targets_flat = plans.TargetArrays(
                jnp.asarray(flat.params), jnp.asarray(flat.brokers),
                jnp.ones_like(jnp.asarray(flat.params)), jnp.asarray(by_param),
                jnp.asarray(by_count))
        return st._targets_flat

    def _flat_table(self, st: ChannelState) -> subs.SubscriptionTable:
        if st._flat is None:
            groups = st._groups or st.aggregator.build()
            sids, params, brokers = [], [], []
            for g in range(groups.num_groups):
                n = int(groups.group_counts[g])
                sids.extend(groups.group_sids[g, :n].tolist())
                params.extend([int(groups.group_params[g])] * n)
                brokers.extend([int(groups.group_brokers[g])] * n)
            st._flat = subs.SubscriptionTable(
                np.asarray(sids, np.int32), np.asarray(params, np.int32),
                np.asarray(brokers, np.int32))
        return st._flat

    def group_sids_array(self, channel: str, aggregated: bool) -> jnp.ndarray:
        st = self.channels[channel]
        if aggregated:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            return jnp.asarray(groups.group_sids)
        flat = self._flat_table(st)
        return jnp.asarray(flat.sids)[:, None]

    @functools.lru_cache(maxsize=256)
    def _exec_fn(self, channel: str, flags: plans.ExecutionFlags,
                 spatial: bool, max_cand: Optional[int] = None) -> Callable:
        st = self.channels[channel]
        spec = st.spec
        conds_one = compile_conditions([list(spec.fixed_preds)])
        best_pred = int(np.argmax([_pred_rank(p) for p in spec.fixed_preds])) \
            if spec.fixed_preds else 0
        max_window = self.max_window
        max_cand = max_cand or self.max_candidates
        num_brokers = self.brokers.num_brokers
        use_pallas = self.use_pallas
        ch_idx = st.index

        def run(ds, index_state, targets, up_mask, last_ts, last_size,
                user_locations, user_brokers):
            if flags.scan_mode == "full":
                cand = plans.candidates_full_scan(ds, conds_one, last_ts, max_cand)
            elif flags.scan_mode == "window":
                cand = plans.candidates_window(ds, conds_one, last_size, max_window)
            elif flags.scan_mode == "trad_index":
                cand = plans.candidates_trad_index(ds, conds_one, best_pred,
                                                   last_size, max_window, max_cand)
            else:
                cand = plans.candidates_bad_index(ds, index_state, ch_idx, max_cand)
            if spatial:
                spatial_fn = None
                if use_pallas:
                    from repro.kernels.spatial_match import ops as sm_ops
                    spatial_fn = sm_ops.spatial_match
                return plans.join_spatial(ds, cand, user_locations, user_brokers,
                                          spec.spatial_radius, spec.payload_bytes,
                                          num_brokers, spatial_fn)
            return plans.join_param_targets(
                ds, cand, targets, spec.param_field, spec.payload_bytes,
                num_brokers, up_mask if flags.param_pushdown else None,
                flags.aggregation)

        return jax.jit(run)

    def execute_channel(self, channel: str,
                        flags: plans.ExecutionFlags,
                        advance: bool = True,
                        timed: bool = True) -> ExecutionReport:
        st = self.channels[channel]
        spatial = st.spec.join == "spatial"
        # The BAD index knows its exact candidate count before execution (the
        # watermark delta) — unlike scans/traditional indexes — so downstream
        # buffers are shape-bucketed to the real volume ("early result
        # filtering" paying off structurally, not just in rows scanned).
        max_cand = None
        if flags.scan_mode == "bad_index":
            pending = int(self.index_state.counts[st.index]
                          - self.index_state.watermarks[st.index])
            bucket = 1 << max(6, (max(pending, 1) - 1).bit_length())
            max_cand = min(bucket, self.max_candidates)
        fn = self._exec_fn(channel, flags, spatial, max_cand)
        targets = self._targets(st, flags.aggregation)
        up_mask = st.user_params.mask()
        args = (self.dataset, self.index_state, targets, up_mask,
                jnp.asarray(st.last_exec_ts, jnp.int32),
                jnp.asarray(st.last_exec_size, jnp.int32),
                self.user_locations, self.user_brokers)
        if timed:  # warm the trace so wall time measures execution, not tracing
            jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        result = fn(*args)
        jax.block_until_ready(result.num_results)
        wall = time.perf_counter() - t0
        if advance:
            self.index_state = bidx.advance_watermark(self.index_state, st.index)
            st.last_exec_ts = self.now
            st.last_exec_size = int(self.dataset.size)
            st.executions += 1
        return ExecutionReport(
            channel=channel, flags=flags, result=result, wall_time_s=wall,
            num_results=int(result.num_results),
            num_notified=int(result.num_notified),
            scanned=int(result.scanned),
            broker_bytes=np.asarray(result.broker_bytes))


def _pred_rank(p) -> int:
    """Heuristic selectivity rank for picking the traditional-index field."""
    from repro.core.predicates import EQ
    return 2 if p.op == EQ else 1


# jit-compiled shared helpers (module-level so lru caches are shared)
_append = R.append
_insert = bidx.insert
