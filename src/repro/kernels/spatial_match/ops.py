"""Jit'd public wrapper for spatial_match: padding + backend dispatch.

Padding uses +inf sentinel coordinates so padded rows/cols never match.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spatial_match.kernel import (DEFAULT_TR, DEFAULT_TU,
                                                spatial_match_kernel)

_FAR = 1e30


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spatial_match(tweet_locs: jnp.ndarray, user_locs: jnp.ndarray,
                  radius) -> jnp.ndarray:
    """(R, 2) x (U, 2) -> (R, U) bool; drop-in for ref.spatial_match."""
    return _padded(tweet_locs, user_locs,
                   jnp.asarray(radius, jnp.float32) ** 2,
                   interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("tr", "tu", "interpret"))
def _padded(tweet_locs, user_locs, radius2, tr: int = DEFAULT_TR,
            tu: int = DEFAULT_TU, interpret: bool = True):
    r, u = tweet_locs.shape[0], user_locs.shape[0]
    rp, up = -r % tr, -u % tu
    if rp:
        tweet_locs = jnp.pad(tweet_locs, ((0, rp), (0, 0)), constant_values=_FAR)
    if up:
        user_locs = jnp.pad(user_locs, ((0, up), (0, 0)), constant_values=-_FAR)
    out = spatial_match_kernel(tweet_locs, user_locs, radius2, tr=tr, tu=tu,
                               interpret=interpret)
    return out[:r, :u].astype(jnp.bool_)
