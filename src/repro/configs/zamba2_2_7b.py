"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240,
vocab=32000, ssm_state=64. Mamba2 backbone + ONE shared attention block
applied every 6 mamba layers (9 applications, weight-shared).
[arXiv:2411.15242; hf]. Mamba2 state + small shared-attn KV -> runs long_500k.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
        vocab_size=32000, head_dim=80, qkv_bias=False, rope_theta=1e4,
        block_pattern=("shared_attn", "mamba", "mamba", "mamba",
                       "mamba", "mamba", "mamba"),
        superlayer_repeat=9,
        ssm_state=64, ssm_expand=2, ssm_chunk=256,
        param_dtype=jnp.bfloat16, grad_accum=8, optimizer="adamw",
        sub_quadratic=True,
    ).validate()
