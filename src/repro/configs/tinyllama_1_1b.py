"""tinyllama-1.1b [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000. llama2-arch small. [arXiv:2401.02385; hf]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=5632,
        vocab_size=32000, head_dim=64, qkv_bias=False, rope_theta=1e4,
        block_pattern=("dense",), superlayer_repeat=22,
        param_dtype=jnp.bfloat16, grad_accum=8, optimizer="adamw",
        sub_quadratic=False,
    ).validate()
