"""Table 1: channel execution time, original vs aggregated subscriptions
(population-skewed 50-state subscription set)."""
from __future__ import annotations

import numpy as np

from repro.core.plans import ExecutionFlags
from benchmarks.common import build_drug_engine, emit, exec_time


def run(rng) -> None:
    eng = build_drug_engine(rng, match_rate=0.05)
    t_orig, i_orig = exec_time(eng, "TweetsAboutDrugs",
                               ExecutionFlags(scan_mode="window"))
    t_agg, i_agg = exec_time(eng, "TweetsAboutDrugs",
                             ExecutionFlags(scan_mode="window", aggregation=True))
    emit("table1/original", t_orig,
         f"results={i_orig['results']};bytes={i_orig['bytes']:.0f}")
    emit("table1/aggregated", t_agg,
         f"results={i_agg['results']};bytes={i_agg['bytes']:.0f}")
    emit("table1/speedup", t_orig - t_agg,
         f"x{t_orig / max(t_agg, 1e-9):.2f} (paper: x4.46)")


if __name__ == "__main__":
    run(np.random.default_rng(0))
