"""Adafactor (Shazeer & Stern 2018): factored 2nd moment + bf16 1st moment.

The memory plan for the >=42B assigned archs: for an (..., R, C) weight the
second moment stores row/col factors (R + C floats instead of R*C), the first
moment is bf16. RMS update clipping per the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    count: jnp.ndarray
    m: Any        # bf16 first moments (or None leaves if beta1 == 0)
    v_row: Any    # factored second moments (2D+), or full v (1D)
    v_col: Any


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    decay: float = 0.8          # beta2 = 1 - count^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    moment_dtype: Any = jnp.bfloat16

    def init(self, params) -> AdafactorState:
        def vrow(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vcol(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        if self.b1 > 0:
            m = jax.tree.map(lambda p: jnp.zeros(p.shape, self.moment_dtype),
                             params)
        else:   # T5 setting: no first moment at all (the 405B memory plan)
            m = jax.tree.map(lambda p: jnp.zeros((1,), self.moment_dtype), params)
        return AdafactorState(jnp.zeros((), jnp.int32), m,
                              jax.tree.map(vrow, params),
                              jax.tree.map(vcol, params))

    def update(self, grads, state: AdafactorState, params):
        count = state.count + 1
        cf = count.astype(jnp.float32)
        beta2 = 1.0 - cf ** (-self.decay)
        lr = self.lr(count)

        def upd(g, m, vr, vc, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + self.eps
            if p.ndim >= 2:
                vr32 = vr * beta2 + jnp.mean(g2, axis=-1) * (1 - beta2)
                vc32 = vc * beta2 + jnp.mean(g2, axis=-2) * (1 - beta2)
                r = vr32 / jnp.maximum(
                    jnp.mean(vr32, axis=-1, keepdims=True), self.eps)
                precond = (r[..., None] * vc32[..., None, :])
                step = gf * jax.lax.rsqrt(precond + self.eps)
            else:
                vr32 = vr * beta2 + g2 * (1 - beta2)
                vc32 = vc
                step = gf * jax.lax.rsqrt(vr32 + self.eps)
            # RMS clipping
            rms = jnp.sqrt(jnp.mean(step * step) + self.eps)
            step = step / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.b1 > 0:
                m32 = m.astype(jnp.float32) * self.b1 + step * (1 - self.b1)
                step = m32
                m_out = m32.astype(self.moment_dtype)
            else:
                m_out = m
            if p.ndim >= 2 and self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, m_out, vr32, vc32

        out = jax.tree.map(upd, grads, state.m, state.v_row, state.v_col, params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), AdafactorState(count, pick(1), pick(2), pick(3))

    def state_pspecs(self, param_pspecs):
        from jax.sharding import PartitionSpec as P

        def vrow_spec(s):
            return P(*tuple(s)[:-1]) if len(tuple(s)) >= 2 else s

        def vcol_spec(s):
            t = tuple(s)
            return P(*(t[:-2] + t[-1:])) if len(t) >= 2 else P(None)

        is_p = lambda x: isinstance(x, P)
        m_specs = (param_pspecs if self.b1 > 0
                   else jax.tree.map(lambda s: P(None), param_pspecs,
                                     is_leaf=is_p))
        return AdafactorState(
            P(),
            m_specs,
            jax.tree.map(vrow_spec, param_pspecs, is_leaf=is_p),
            jax.tree.map(vcol_spec, param_pspecs, is_leaf=is_p))
